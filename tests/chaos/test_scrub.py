"""CRC scrubber tests: detection of every injected chunkstore corruption,
level-1 repair, quarantine + typed restore failure (DESIGN.md §13)."""

import os
import random

import numpy as np
import pytest

from repro.core import delta as delta_mod
from repro.core import faults
from repro.core.checkpoint import CheckpointManager
from repro.core.engines import EngineConfig
from repro.core.multilevel import MultiLevelCheckpointer


def _cfg():
    return EngineConfig(backend="posix", strategy="file_per_tensor",
                        direct=False)


def _state(seed):
    r = np.random.default_rng(seed)
    return {"w": r.standard_normal((128, 16)).astype(np.float32),
            "emb": r.integers(0, 256, 4096).astype(np.uint8)}


def _fp(state):
    return {k: np.asarray(v).tobytes() for k, v in state.items()}


def _delta_mgr(root, **kw):
    mgr = CheckpointManager(root, config=_cfg(), keep=None, delta=True,
                            delta_chunk_bytes=1024, **kw)
    mgr.delta_gc_grace_s = 0.0
    return mgr


def _save_steps(mgr, n=3):
    fps = {}
    r = random.Random(7)
    state = _state(0)
    for step in range(1, n + 1):
        mgr.save(step, state)
        fps[step] = _fp(state)
        nxt = _state(step)
        # partial mutation: later steps share clean chunks with earlier ones
        nxt["emb"] = state["emb"].copy()
        state = nxt
    return fps


def test_scrub_clean_store_reports_nothing(tmp_ckpt_dir):
    mgr = _delta_mgr(tmp_ckpt_dir)
    _save_steps(mgr)
    mgr.close()
    rep = faults.scrub_store(tmp_ckpt_dir)
    assert rep.clean
    assert rep.files_scanned > 0 and rep.chunks_checked > 0
    assert not rep.corrupt and not rep.quarantined and not rep.repaired


def test_scrub_detects_every_injected_corruption(tmp_ckpt_dir):
    mgr = _delta_mgr(tmp_ckpt_dir)
    _save_steps(mgr)
    mgr.close()
    refs = faults.referenced_chunks(tmp_ckpt_dir)
    assert refs, "no store-referenced chunks — scenario broken"
    rng = random.Random(11)
    hit = set()
    store = os.path.join(tmp_ckpt_dir, delta_mod.CHUNKSTORE_DIR)
    for rel in sorted(refs)[:4]:           # corrupt several distinct files
        off, nbytes = refs[rel][0][0], refs[rel][0][1]
        faults.flip_byte(os.path.join(store, rel),
                         off + rng.randrange(max(nbytes, 1)))
        hit.add(rel)
    rep = faults.scrub_store(tmp_ckpt_dir)
    assert set(rep.corrupt) == hit         # every corruption, nothing else
    assert set(rep.quarantined) == hit     # no mirror: all quarantined
    for rel in hit:
        assert os.path.exists(os.path.join(
            store, faults.QUARANTINE_SUBDIR, rel))
        assert not os.path.exists(os.path.join(store, rel))


def test_scrub_repairs_from_level1_and_restore_is_bit_exact(tmp_path):
    local, remote = str(tmp_path / "l0"), str(tmp_path / "l1")
    ml = MultiLevelCheckpointer(local, remote, config=_cfg(), keep=None,
                                delta=True, delta_chunk_bytes=1024)
    ml.local.delta_gc_grace_s = 0.0
    fps = _save_steps(ml)
    ml.wait()
    ml.close()
    hit = faults.corrupt_store_chunk(local, random.Random(3))
    assert hit is not None
    rel, _ = hit
    rep = faults.scrub_store(local, remote_root=remote)
    assert rep.corrupt == [rel]
    assert rep.repaired == [rel] and not rep.quarantined
    # repaired in place: a second scrub is clean, restores are bit-exact
    assert faults.scrub_store(local, remote_root=remote).clean
    v = CheckpointManager(local, config=_cfg(), keep=None)
    for step, fp in fps.items():
        assert _fp(v.restore(step=step)) == fp
    v.close()


def test_scrub_quarantine_with_corrupt_mirror_too(tmp_path):
    """A mirror that is itself corrupt must not be copied in as a repair."""
    local, remote = str(tmp_path / "l0"), str(tmp_path / "l1")
    ml = MultiLevelCheckpointer(local, remote, config=_cfg(), keep=None,
                                delta=True, delta_chunk_bytes=1024)
    ml.local.delta_gc_grace_s = 0.0
    _save_steps(ml)
    ml.wait()
    ml.close()
    hit = faults.corrupt_store_chunk(local, random.Random(5))
    assert hit is not None
    rel, off = hit
    faults.flip_byte(os.path.join(remote, delta_mod.CHUNKSTORE_DIR, rel),
                     off)
    rep = faults.scrub_store(local, remote_root=remote)
    assert rep.corrupt == [rel]
    assert rep.quarantined == [rel] and not rep.repaired


def test_restore_after_quarantine_raises_typed_error(tmp_ckpt_dir):
    mgr = _delta_mgr(tmp_ckpt_dir)
    fps = _save_steps(mgr)
    mgr.close()
    hit = faults.corrupt_store_chunk(tmp_ckpt_dir, random.Random(9))
    assert hit is not None
    rel, _ = hit
    rep = faults.scrub_store(tmp_ckpt_dir)
    assert rep.quarantined == [rel]
    v = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    outcomes = {}
    for step, fp in fps.items():
        try:
            outcomes[step] = _fp(v.restore(step=step)) == fp
        except faults.QuarantinedChunkError as e:
            # typed failure must name the quarantined chunk
            assert rel in e.store_path
            outcomes[step] = "typed"
    # at least one step depended on the chunk; none returned wrong bytes
    assert "typed" in outcomes.values()
    assert False not in outcomes.values()
    v.close()


def test_scrub_ignores_unreferenced_files(tmp_ckpt_dir):
    mgr = _delta_mgr(tmp_ckpt_dir)
    _save_steps(mgr)
    mgr.close()
    stray = os.path.join(tmp_ckpt_dir, delta_mod.CHUNKSTORE_DIR,
                         delta_mod.PACK_SUBDIR, "stray", "junk.bin")
    os.makedirs(os.path.dirname(stray))
    with open(stray, "wb") as f:
        f.write(os.urandom(256))
    rep = faults.scrub_store(tmp_ckpt_dir)
    assert rep.clean                      # unreferenced bytes are GC's job
