"""Chaos harness tier-1 tests: fault-plan mechanics, targeted fault
scenarios over the live checkpoint stack, a small seeded campaign, and the
two canary tests proving the campaign detects the historical publish/GC
bugs when their fixes are reverted (DESIGN.md §13)."""

import errno
import os
import shutil

import numpy as np
import pytest

from repro.core import checkpoint as ckpt_mod
from repro.core import chaos
from repro.core import delta as delta_mod
from repro.core import faults
from repro.core.checkpoint import CheckpointManager
from repro.core.engines import EngineConfig
from repro.core.manifest import Manifest, ManifestError


def _cfg(strategy="single_file"):
    return EngineConfig(backend="posix", strategy=strategy, direct=False)


def _state(seed=0):
    r = np.random.default_rng(seed)
    return {"w": r.standard_normal((64, 8)).astype(np.float32),
            "b": r.standard_normal(32)}


def _fp(state):
    return {k: (str(np.asarray(v).dtype), np.asarray(v).tobytes())
            for k, v in state.items()}


# ---------------------------------------------------------------- plan units
def test_fault_fires_at_nth_eligible_call_only():
    plan = faults.FaultPlan([faults.Fault(faults.OP_WRITE, at=3,
                                          action=faults.A_ERRNO,
                                          err=errno.EIO)])
    f = plan.faults[0]
    assert plan._consult(faults.OP_WRITE) is None       # 1st
    assert plan._consult(faults.OP_FSYNC) is None       # other op: not seen
    assert f.seen == 1
    assert plan._consult(faults.OP_WRITE) is None       # 2nd
    hit = plan._consult(faults.OP_WRITE)                # 3rd: fires
    assert hit is f and f.done
    assert plan._consult(faults.OP_WRITE) is None       # one-shot
    assert plan.fired == [f.describe()]
    assert plan.counts[faults.OP_WRITE] == 4


def test_fault_path_filter_gates_eligibility():
    plan = faults.FaultPlan([faults.Fault(
        faults.OP_RENAME, at=1, path_contains="manifest")])
    assert plan._consult(faults.OP_RENAME, "/a/data.bin\x00/a/data2.bin") \
        is None
    assert plan._consult(faults.OP_RENAME,
                         "/a/manifest.json.tmp\x00/a/manifest.json") \
        is plan.faults[0]


def test_fault_rejects_bad_specs():
    with pytest.raises(ValueError):
        faults.Fault("chmod")
    with pytest.raises(ValueError):
        faults.Fault(faults.OP_WRITE, at=0)
    with pytest.raises(ValueError):
        faults.Fault(faults.OP_WRITE, action="explode")


def test_inject_rejects_nesting_and_disarms():
    plan = faults.FaultPlan()
    with faults.inject(plan):
        with pytest.raises(RuntimeError):
            with faults.inject(faults.FaultPlan()):
                pass
    # disarmed on exit: shims are pass-through again
    assert faults._ACTIVE is None


def test_shims_are_passthrough_when_unarmed(tmp_path):
    p = str(tmp_path / "f")
    fd = os.open(p, os.O_CREAT | os.O_RDWR, 0o644)
    try:
        assert faults.pwrite(fd, b"abcdef", 0) == 6
        buf = bytearray(6)
        assert faults.preadv(fd, [memoryview(buf)], 0) == 6
        assert bytes(buf) == b"abcdef"
        faults.fsync(fd)
        faults.fdatasync(fd)
    finally:
        os.close(fd)
    faults.replace(p, p + ".2")
    assert os.path.exists(p + ".2")


# ------------------------------------------------------- targeted fault tests
def test_torn_write_crash_preserves_previous_step(tmp_ckpt_dir):
    mgr = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    s1 = _state(1)
    mgr.save(1, s1)
    plan = faults.FaultPlan([faults.Fault(faults.OP_WRITE, at=1,
                                          action=faults.A_TORN, frac=0.4)])
    with faults.inject(plan):
        with pytest.raises(Exception) as ei:
            mgr.save(2, _state(2))
    assert any(isinstance(e, faults.InjectedCrash)
               for e in chaos._chain(ei.value))
    assert plan.fired
    mgr.close()
    faults.simulate_owner_death(tmp_ckpt_dir)
    v = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    assert 1 in v.all_steps()
    assert _fp(v.restore(step=1)) == _fp(s1)
    # the torn step either never committed, or committed whole
    if 2 in v.all_steps():
        assert _fp(v.restore(step=2)) == _fp(_state(2))
    v.close()


def test_enospc_surfaces_and_manager_survives(tmp_ckpt_dir):
    mgr = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    plan = faults.FaultPlan([faults.Fault(faults.OP_WRITE, at=2,
                                          action=faults.A_ERRNO,
                                          err=errno.ENOSPC)])
    with faults.inject(plan):
        with pytest.raises(Exception) as ei:
            mgr.save(1, _state(1))
    assert any(isinstance(e, faults.InjectedIOError)
               and e.errno == errno.ENOSPC for e in chaos._chain(ei.value))
    # an ENOSPC-failed save must not wedge the manager: retry commits
    s2 = _state(2)
    mgr.save(2, s2)
    assert _fp(mgr.restore(step=2)) == _fp(s2)
    mgr.close()


def test_fsync_crash_never_commits_partial_step(tmp_ckpt_dir):
    mgr = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    s1 = _state(3)
    mgr.save(1, s1)
    plan = faults.FaultPlan([faults.Fault(faults.OP_FSYNC, at=1)])
    with faults.inject(plan):
        with pytest.raises(Exception):
            mgr.save(2, _state(4))
    mgr.close()
    faults.simulate_owner_death(tmp_ckpt_dir)
    v = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    assert _fp(v.restore(step=1)) == _fp(s1)
    v.close()


def test_resave_rename_crash_keeps_a_valid_version(tmp_ckpt_dir):
    """Crashing the publish rename while re-saving an existing step must
    leave SOME complete version of the step (old or new) restorable —
    the displaced-aside publish contract."""
    mgr = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    old = _state(5)
    mgr.save(1, old)
    new = _state(6)
    plan = faults.FaultPlan([faults.Fault(faults.OP_RENAME, at=2)])
    with faults.inject(plan):
        try:
            mgr.save(1, new)
        except Exception as e:
            assert any(isinstance(x, faults.InjectedCrash)
                       for x in chaos._chain(e))
    mgr.close()
    faults.simulate_owner_death(tmp_ckpt_dir)
    v = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    assert 1 in v.all_steps()
    assert _fp(v.restore(step=1)) in (_fp(old), _fp(new))
    v.close()


def test_manifest_zeroed_falls_back_to_previous_step(tmp_ckpt_dir):
    """Satellite regression: a zero-byte manifest.json raises typed
    ManifestError on direct load, and latest-step restore falls back."""
    mgr = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    s1, s2 = _state(7), _state(8)
    mgr.save(1, s1)
    mgr.save(2, s2)
    mgr.close()
    faults.zero_file(os.path.join(tmp_ckpt_dir, "step_00000002",
                                  "manifest.json"))
    v = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    with pytest.raises(ManifestError):
        v.restore(step=2)          # explicit step: typed error propagates
    assert _fp(v.restore()) == _fp(s1)   # latest-step fallback
    v.close()


# ------------------------------------------------------------ seeded campaign
def test_campaign_smoke_all_cells():
    stats = chaos.run_campaign(1234, min_faults=36)
    assert stats.faults >= 36
    assert set(stats.by_cell) == set(chaos.CELLS)


def test_campaign_is_deterministic_per_trial(tmp_path):
    a = chaos.run_campaign(9, min_faults=6, max_trials=6,
                           base_dir=str(tmp_path / "a"))
    b = chaos.run_campaign(9, min_faults=6, max_trials=6,
                           base_dir=str(tmp_path / "b"))
    assert a.by_kind == b.by_kind and a.trials == b.trials


# ------------------------------------------------------------------- canaries
def test_canary_naive_publish_loses_committed_step(tmp_ckpt_dir,
                                                   monkeypatch):
    """Revert the displaced-aside publish (PR 4) to naive rmtree+rename:
    a crash between the two must now lose the committed step — proving
    the harness would catch the regression. The real publish survives the
    identical injection (test_resave_rename_crash_keeps_a_valid_version)."""
    def naive_replace_dir(tmp, final):
        if os.path.exists(final):
            shutil.rmtree(final)           # the unprotected window
        faults.replace(tmp, final)
    monkeypatch.setattr(ckpt_mod, "replace_dir", naive_replace_dir)
    mgr = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    mgr.save(1, _state(5))
    # rename #1 is the manifest tmp-file; #2 is the step-dir publish
    plan = faults.FaultPlan([faults.Fault(faults.OP_RENAME, at=2)])
    with faults.inject(plan):
        with pytest.raises(Exception):
            mgr.save(1, _state(6))
    assert plan.fired
    mgr.close()
    faults.simulate_owner_death(tmp_ckpt_dir)
    v = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    assert 1 not in v.all_steps(), \
        "naive publish unexpectedly kept the step — canary lost its teeth"
    v.close()


def test_canary_unpinned_gc_reaps_fresh_chunks(tmp_ckpt_dir, monkeypatch):
    """Revert the tmp-manifest pinning (PR 5): a refcount GC running while
    publish_packs moves chunks into the store reaps them, leaving the
    committed step referencing missing bytes — caught by scrub + restore.
    Second half: the REAL pinning survives the identical injection."""
    def committed_only_refs(root):
        counts: dict = {}
        for d in sorted(os.listdir(root)):
            p = os.path.join(root, d)
            if not (d.startswith("step_") and os.path.isdir(p)
                    and ".tmp" not in d):
                continue
            try:
                m = Manifest.load(p)
            except ManifestError:
                continue
            for rel in delta_mod.manifest_store_paths(m):
                counts[rel] = counts.get(rel, 0) + 1
        return counts

    def run(patch_refs: bool) -> bool:
        """True when the committed step survives intact."""
        root = os.path.join(tmp_ckpt_dir, "pinned" if not patch_refs
                            else "unpinned")
        with monkeypatch.context() as mp:
            if patch_refs:
                mp.setattr(delta_mod, "referenced_store_paths",
                           committed_only_refs)
            mgr = CheckpointManager(
                root, config=_cfg("file_per_tensor"), keep=None,
                delta=True, delta_chunk_bytes=512)
            mgr.delta_gc_grace_s = 0.0
            mgr.save(1, _state(1))
            gc = lambda: delta_mod.gc_store(root, grace_s=0.0)
            # by rename #2 into the chunkstore, chunk files from THIS save
            # are already in the store, referenced only by the tmp manifest
            plan = faults.FaultPlan([faults.Fault(
                faults.OP_RENAME, at=2, action=faults.A_CALL, callback=gc,
                path_contains=delta_mod.CHUNKSTORE_DIR)])
            with faults.inject(plan):
                mgr.save(2, _state(2))
            assert plan.fired, "gc callback never ran: adjust fault site"
            mgr.close()
        if not faults.scrub_store(root).clean:
            return False
        v = CheckpointManager(root, config=_cfg(), keep=None)
        try:
            ok = _fp(v.restore(step=2)) == _fp(_state(2))
        except Exception:
            ok = False
        v.close()
        return ok

    assert not run(patch_refs=True), \
        "unpinned GC did not corrupt the step — canary lost its teeth"
    assert run(patch_refs=False), \
        "real pinning failed under the same injection"


# ------------------------------------------ shimmed rmtree + promote canary
def test_torn_rmtree_leaves_partial_tree_and_crashes(tmp_path):
    """faults.rmtree models a crash mid-deletion: a prefix of the files is
    gone, the rest (and the dirs) survive — and the injected crash surfaces
    even under ignore_errors=True."""
    root = tmp_path / "victim"
    for i in range(4):
        d = root / f"sub{i}"
        d.mkdir(parents=True)
        (d / "f.bin").write_bytes(b"x" * 64)
    plan = faults.FaultPlan([faults.Fault(faults.OP_RMTREE, at=1,
                                          action=faults.A_TORN, frac=0.5)])
    with faults.inject(plan):
        with pytest.raises(faults.InjectedCrash):
            faults.rmtree(str(root), ignore_errors=True)
    assert plan.fired
    left = list(root.rglob("f.bin"))
    assert root.exists() and 0 < len(left) < 4


def test_keep_gc_rmtree_is_fault_visible(tmp_ckpt_dir):
    """The keep-GC tree deletion routes through the shim now: an injected
    EIO on the old step's rmtree surfaces (it used to escape the chaos
    plan entirely via raw shutil.rmtree), and since the new step published
    before GC runs, both steps stay whole and restorable."""
    mgr = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=1)
    s1, s2 = _state(1), _state(2)
    mgr.save(1, s1)
    plan = faults.FaultPlan([faults.Fault(
        faults.OP_RMTREE, at=1, action=faults.A_ERRNO, err=errno.EIO,
        path_contains=ckpt_mod.step_dir_name(1))])
    with faults.inject(plan):
        with pytest.raises(Exception) as ei:
            mgr.save(2, s2)
    assert any(isinstance(e, faults.InjectedIOError)
               for e in chaos._chain(ei.value))
    assert plan.fired
    mgr.close()
    v = CheckpointManager(tmp_ckpt_dir, config=_cfg(), keep=None)
    assert set(v.all_steps()) >= {1, 2}
    assert _fp(v.restore(step=1)) == _fp(s1)
    assert _fp(v.restore(step=2)) == _fp(s2)
    v.close()


def test_prefetch_promote_crash_never_loses_previous_copy(tmp_path):
    """Canary for the rmtree-then-rename promote bug: RestorePrefetcher's
    promote over an EXISTING level-0 step now goes through replace_dir's
    displaced-aside protocol, so a crash at either rename leaves the old
    copy on disk (as the final dir or a rollback-able .tmp-old- aside)."""
    from repro.core.tiered import RestorePrefetcher
    local = tmp_path / "local"
    local.mkdir()
    final = local / ckpt_mod.step_dir_name(7)
    final.mkdir()
    (final / "sentinel.bin").write_bytes(b"previous-version")
    staged = str(final) + RestorePrefetcher.STAGING_SUFFIX
    os.makedirs(staged)
    with open(os.path.join(staged, "new.bin"), "wb") as f:
        f.write(b"new-version")
    pf = RestorePrefetcher(str(tmp_path / "remote"))
    pf._active[staged] = {"manifest": Manifest(step=7, num_ranks=1, strategy="single_file"),
                          "fetched": {}}
    plan = faults.FaultPlan([faults.Fault(faults.OP_RENAME, at=2,
                                          action=faults.A_CRASH)])
    with faults.inject(plan):
        with pytest.raises(faults.InjectedCrash):
            pf.finish(staged, str(final))
    assert plan.fired
    asides = list(local.glob(ckpt_mod.step_dir_name(7) + ".tmp-old-*"))
    assert final.exists() or (
        asides
        and (asides[0] / "sentinel.bin").read_bytes() == b"previous-version"
    ), "crash mid-promote lost BOTH the old and the new copy"


# ------------------------------------------------------------- trace forensics
def test_violation_dump_contains_injected_fault_event(tmp_path, monkeypatch):
    """A broken invariant keeps the trial dir AND drops a Perfetto
    trace.json beside it whose events include every injected fault —
    op kind, action, and target path (DESIGN.md §17)."""
    import json
    import random

    def boom(t, stats):
        mgr = CheckpointManager(t.root, engine="aggregated", config=_cfg(),
                                async_save=False, keep=2)
        plan = faults.FaultPlan([faults.Fault(faults.OP_RENAME, at=1,
                                              action=faults.A_ERRNO,
                                              err=errno.ENOSPC)])
        try:
            with faults.inject(plan):
                try:
                    mgr.save(1, _state())
                except OSError:
                    pass
        finally:
            mgr.close()
        assert plan.fired
        t.fault_desc = plan.fired[0]
        t.die("forced violation for the forensics dump")

    monkeypatch.setattr(chaos, "_trial_single", boom)
    stats = chaos.CampaignStats(seed=0)
    with pytest.raises(chaos.InvariantViolation):
        chaos.run_trial("solo", random.Random(0), str(tmp_path), stats)
    kept = [d for d in tmp_path.iterdir() if d.is_dir()]
    assert len(kept) == 1, "violation must keep the trial dir"
    doc = json.loads((kept[0] / "trace.json").read_text())
    fired = [e for e in doc["traceEvents"]
             if e.get("ph") == "i" and e.get("name") == "fault.injected"]
    assert fired, "the injected fault never became a trace event"
    args = fired[0]["args"]
    assert args["op"] == faults.OP_RENAME
    assert args["action"] == faults.A_ERRNO
    assert args["path"], "rename faults must carry the target path"
    # the save's spans ride in the same dump: forensics sees the timeline
    spans = {e["name"] for e in doc["traceEvents"] if e.get("ph") == "X"}
    assert "save" in spans
