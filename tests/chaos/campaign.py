#!/usr/bin/env python
"""Seeded chaos gate — the ``make chaos`` entry point (DESIGN.md §13).

Runs the deterministic campaign on a fixed seed set and fails loudly on
any invariant violation or on insufficient fault coverage (>= 200 faults
must actually fire, spanning every fault kind class). Budgeted well under
60 s. Set ``CHAOS_ITERS=N`` to append N extra random-seed campaigns (the
nightly/soak mode); each extra seed is printed so a failure reproduces.

Usage:  PYTHONPATH=src python tests/chaos/campaign.py
"""

import os
import sys

from repro.core import chaos

GATE_SEEDS = (0, 42)
MIN_FAULTS = 200
# every kind class must appear across the gate run (prefixes of by_kind);
# crash:gather = a crash in the fingerprint-diff -> put D2H gather window;
# the r{put,get} / corrupt:remote classes cover the level-2 object tier
# (DESIGN.md §15): crashed uploads, stalled/short/errored range reads,
# and damaged remote objects
REQUIRED_KINDS = ("crash:", "torn:", "short:", "errno:", "corrupt:",
                  "crash:gather", "errno:gather",
                  "crash:rput", "errno:rget", "stall:rget", "short:rget",
                  "corrupt:remote")


def main() -> int:
    seeds = list(GATE_SEEDS)
    extra = int(os.environ.get("CHAOS_ITERS", "0"))
    for _ in range(extra):
        seeds.append(int.from_bytes(os.urandom(4), "little"))

    total = 0
    kinds: set = set()
    for seed in seeds:
        try:
            stats = chaos.run_campaign(seed, min_faults=MIN_FAULTS)
        except chaos.InvariantViolation as e:
            print(f"INVARIANT VIOLATION (seed {seed})\n{e}")
            return 1
        print(stats.summary())
        total += stats.faults
        kinds.update(stats.by_kind)

    missing = [p for p in REQUIRED_KINDS
               if not any(k.startswith(p) for k in kinds)]
    if missing:
        print(f"FAIL: fault kind classes never fired: {missing}")
        return 1
    if total < MIN_FAULTS:
        print(f"FAIL: only {total} faults fired (< {MIN_FAULTS})")
        return 1
    print(f"chaos gate OK: {total} faults across {len(seeds)} seeds, "
          f"zero invariant violations")
    return 0


if __name__ == "__main__":
    sys.exit(main())
