"""Property tests for the delta path: diff → dirty-chunk write → restore
is bit-exact for random dirty masks, grid sizes and dtype mixes, and a
chunk-grid change degrades to a full rewrite without losing exactness
(DESIGN.md §12)."""

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import CheckpointManager, EngineConfig
from repro.core import delta as delta_mod
from repro.core.manifest import Manifest

DTYPES = ("float32", "float64", "int32", "int16", "uint8")


def _cfg():
    return EngineConfig(backend="posix", strategy="file_per_tensor",
                        direct=False)


def _make_state(specs, seed):
    r = np.random.default_rng(seed)
    state = {}
    for i, (dt, n) in enumerate(specs):
        dtype = np.dtype(dt)
        if dtype.kind in "iu":
            info = np.iinfo(dtype)
            state[f"t{i}"] = r.integers(info.min, info.max, n,
                                        dtype=np.int64).astype(dtype)
        else:
            state[f"t{i}"] = r.standard_normal(n).astype(dtype)
    return state


def _dirty_mutate(state, chunk_bytes, frac, seed):
    """Dirty a random subset of each tensor's chunk-grid cells."""
    r = np.random.default_rng(seed)
    out = {}
    for k, v in state.items():
        a = v.copy()
        nchunks = max(1, (a.nbytes + chunk_bytes - 1) // chunk_bytes)
        mask = r.random(nchunks) < frac
        raw = a.view(np.uint8).reshape(-1)
        per = max(1, chunk_bytes // a.itemsize) * a.itemsize
        for c in np.flatnonzero(mask):
            lo = c * per
            hi = min(lo + per, raw.shape[0])
            if lo < raw.shape[0]:
                raw[lo:hi] = r.integers(0, 256, hi - lo, dtype=np.int64) \
                    .astype(np.uint8)
        out[k] = a
    return out


def _fp(state):
    return {k: (str(np.asarray(v).dtype), np.asarray(v).tobytes())
            for k, v in sorted(state.items())}


@settings(max_examples=8, deadline=None)
@given(chunk=st.sampled_from([256, 1024, 4096]),
       specs=st.lists(st.tuples(st.sampled_from(DTYPES),
                                st.integers(min_value=17, max_value=2500)),
                      min_size=1, max_size=4),
       dirt=st.lists(st.tuples(st.integers(min_value=0, max_value=2 ** 31),
                               st.sampled_from([0.0, 0.1, 0.5, 1.0])),
                     min_size=1, max_size=3))
def test_delta_roundtrip_bit_exact_random_masks(chunk, specs, dirt,
                                                tmp_path_factory):
    d = str(tmp_path_factory.mktemp("dprop"))
    state = _make_state(specs, seed=1)
    fps = {}
    with CheckpointManager(d, config=_cfg(), keep=None, delta=True,
                           delta_chunk_bytes=chunk) as mgr:
        mgr.save(1, state)
        fps[1] = _fp(state)
        for step, (seed, frac) in enumerate(dirt, start=2):
            state = _dirty_mutate(state, chunk, frac, seed)
            mgr.save(step, state)
            fps[step] = _fp(state)
        # every committed step restores bit-exactly — clean chunks are
        # shared through the store, dirty ones rewritten
        for step, fp in fps.items():
            assert _fp(mgr.restore(step=step)) == fp


@settings(max_examples=6, deadline=None)
@given(grids=st.sampled_from([(512, 2048), (2048, 512), (1024, 4096)]),
       spec=st.tuples(st.sampled_from(DTYPES),
                      st.integers(min_value=600, max_value=5000)))
def test_delta_grid_change_degrades_to_full_rewrite(grids, spec,
                                                    tmp_path_factory):
    """Changing delta_chunk_bytes between saves must invalidate the diff
    index (no cross-grid chunk reuse) yet stay bit-exact for both steps."""
    d = str(tmp_path_factory.mktemp("dgrid"))
    g1, g2 = grids
    state1 = _make_state([spec, ("float32", 800)], seed=3)
    with CheckpointManager(d, config=_cfg(), keep=None, delta=True,
                           delta_chunk_bytes=g1) as mgr:
        mgr.save(1, state1)
    state2 = _dirty_mutate(state1, g1, 0.3, seed=4)
    with CheckpointManager(d, config=_cfg(), keep=None, delta=True,
                           delta_chunk_bytes=g2) as mgr:
        mgr.save(2, state2)
        assert _fp(mgr.restore(step=1)) == _fp(state1)
        assert _fp(mgr.restore(step=2)) == _fp(state2)
    m1 = Manifest.load(f"{d}/step_00000001")
    m2 = Manifest.load(f"{d}/step_00000002")
    shared = (set(delta_mod.manifest_store_paths(m1))
              & set(delta_mod.manifest_store_paths(m2)))
    assert not shared, "cross-grid chunk reuse: the size key must miss"


@settings(max_examples=6, deadline=None)
@given(chunk=st.sampled_from([256, 2048]),
       seed=st.integers(min_value=0, max_value=2 ** 31))
def test_delta_unchanged_state_rewrites_nothing_new(chunk, seed,
                                                    tmp_path_factory):
    """A bit-identical re-save references only already-stored chunks."""
    d = str(tmp_path_factory.mktemp("dnoop"))
    state = _make_state([("float32", 1500), ("uint8", 3000)], seed=seed)
    with CheckpointManager(d, config=_cfg(), keep=None, delta=True,
                           delta_chunk_bytes=chunk) as mgr:
        mgr.save(1, state)
        mgr.save(2, {k: v.copy() for k, v in state.items()})
        assert _fp(mgr.restore(step=2)) == _fp(state)
    m1 = Manifest.load(f"{d}/step_00000001")
    m2 = Manifest.load(f"{d}/step_00000002")
    p1 = set(delta_mod.manifest_store_paths(m1))
    chunked2 = [r for rec in m2.tensors.values() for sh in rec.shards
                if delta_mod.is_chunked(sh) and sh.chunks
                for r in sh.chunks]
    assert chunked2, "delta path not engaged"
    assert {r.path[len(delta_mod.STORE_PREFIX):] for r in chunked2} <= p1
