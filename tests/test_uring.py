"""io_uring binding: rings, opcodes, registered buffers, O_DIRECT."""

import ctypes
import mmap
import os

import pytest

from repro.core.uring import IoUring, probe_io_uring

pytestmark = pytest.mark.skipif(not probe_io_uring(),
                                reason="io_uring unavailable")


def _buf(nbytes, fill=None):
    mm = mmap.mmap(-1, nbytes)
    if fill:
        mm.write(fill[:nbytes])
        mm.seek(0)
    addr = ctypes.addressof(ctypes.c_char.from_buffer(mm))
    return mm, addr


def test_nop_roundtrip():
    with IoUring(entries=8) as ring:
        ring.prep_nop(user_data=42)
        assert ring.submit() == 1
        cqes = ring.wait_cqes(1)
        assert cqes[0].user_data == 42 and cqes[0].res == 0


def test_write_read_fsync(tmp_path):
    path = str(tmp_path / "f.bin")
    data = os.urandom(1 << 20)
    wmm, waddr = _buf(1 << 20, data)
    fd = os.open(path, os.O_CREAT | os.O_RDWR, 0o644)
    with IoUring(entries=32) as ring:
        for i in range(4):
            off = i * (1 << 18)
            ring.prep_write(fd, waddr + off, 1 << 18, off, user_data=i)
        ring.submit()
        cqes = ring.wait_cqes(4)
        assert sorted(c.user_data for c in cqes) == [0, 1, 2, 3]
        assert all(c.res == 1 << 18 for c in cqes)
        ring.prep_fsync(fd, user_data=9)
        ring.submit()
        assert ring.wait_cqes(1)[0].res == 0
    rmm, raddr = _buf(1 << 20)
    with IoUring(entries=8) as ring:
        ring.prep_read(fd, raddr, 1 << 20, 0, user_data=7)
        ring.submit()
        assert ring.wait_cqes(1)[0].res == 1 << 20
    rmm.seek(0)
    assert rmm.read(1 << 20) == data
    os.close(fd)


def test_fixed_buffers_odirect(tmp_path):
    path = str(tmp_path / "d.bin")
    data = os.urandom(1 << 16)
    wmm, waddr = _buf(1 << 16, data)
    rmm, raddr = _buf(1 << 16)

    class B:
        def __init__(self, mm, addr):
            self.address, self.nbytes = addr, len(mm)

    try:
        fd = os.open(path, os.O_CREAT | os.O_RDWR | os.O_DIRECT, 0o644)
    except OSError:
        pytest.skip("O_DIRECT unsupported")
    with IoUring(entries=8) as ring:
        ring.register_buffers([B(wmm, waddr), B(rmm, raddr)])
        ring.prep_write_fixed(fd, waddr, 1 << 16, 0, user_data=1, buf_index=0)
        ring.submit()
        assert ring.wait_cqes(1)[0].res == 1 << 16
        ring.prep_read_fixed(fd, raddr, 1 << 16, 0, user_data=2, buf_index=1)
        ring.submit()
        assert ring.wait_cqes(1)[0].res == 1 << 16
    rmm.seek(0)
    assert rmm.read(1 << 16) == data
    os.close(fd)


def test_error_cqe(tmp_path):
    """Read from an fd opened write-only must surface -EBADF/-EACCES."""
    path = str(tmp_path / "e.bin")
    fd = os.open(path, os.O_CREAT | os.O_WRONLY, 0o644)
    mm, addr = _buf(4096)
    with IoUring(entries=8) as ring:
        ring.prep_read(fd, addr, 4096, 0, user_data=1)
        ring.submit()
        cqe = ring.wait_cqes(1)[0]
        assert cqe.res < 0
    os.close(fd)


def test_queue_capacity():
    with IoUring(entries=8) as ring:
        assert ring.sq_space() == 8
        for i in range(8):
            ring.prep_nop(user_data=i)
        assert ring.sq_space() == 0
        ring.submit()
        ring.wait_cqes(8)
        assert ring.sq_space() == 8
